"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container (kernel bodies execute in Python) and compile to Mosaic on
real TPUs.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill as _flash
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_kv"))
def flash_prefill_op(q, k, v, *, causal=True, window=None, q_offset=0,
                     block_q=128, block_kv=128):
    return _flash(q, k, v, causal=causal, window=window, q_offset=q_offset,
                  block_q=block_q, block_kv=block_kv,
                  interpret=not _on_tpu())


@jax.jit
def paged_attention_op(q, k_pages, v_pages, block_tables, seq_lens):
    return _paged(q, k_pages, v_pages, block_tables, seq_lens,
                  interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan_op(X, dA, B_mat, C_mat, *, chunk=64):
    return _ssd(X, dA, B_mat, C_mat, chunk=chunk, interpret=not _on_tpu())


# re-export oracles for benchmarks
flash_prefill_ref = ref.flash_prefill_ref
paged_attention_ref = ref.paged_attention_ref
ssd_scan_ref = ref.ssd_scan_ref

"""Flash attention for (chunked) prefill — Pallas TPU kernel.

Online-softmax attention with causal + sliding-window masking and a
``q_offset`` so a prefill chunk can attend to an already-cached prefix
(the chunked-prefill path of the serving engine).

TPU mapping: grid (B, Hq, Sq/bq, Skv/bkv) with the KV dimension innermost
so the f32 accumulator lives in VMEM scratch across KV steps; tiles are
MXU-aligned (bq, bkv multiples of 128 in production; head_dim on the lane
axis). Fully-masked KV blocks are skipped with ``pl.when`` — for causal
masking this halves the work, for sliding windows it bounds it by
O(window) per query row.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bkv: int, causal: bool, window, q_offset: int,
            kv_steps: int, scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) \
        + q_offset
    k_pos = kj * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    # Block-level relevance: any unmasked element in this tile?
    first_q = qi * bq + q_offset
    last_q = first_q + bq - 1
    first_k = kj * bkv
    last_k = first_k + bkv - 1
    relevant = jnp.asarray(True)
    if causal:
        relevant = relevant & (first_k <= last_q)
    if window is not None:
        relevant = relevant & (last_k > first_q - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)           # [bkv, D]
        v = v_ref[0, 0].astype(jnp.float32)           # [bkv, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        mask = jnp.ones((bq, bkv), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(kj == kv_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, causal: bool = True, window=None,
                  q_offset: int = 0, block_q: int = 128,
                  block_kv: int = 128, interpret: bool = False):
    """q [B, Hq, Sq, D], k/v [B, Hkv, Skv, D] -> [B, Hq, Sq, D]."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)
    kv_steps = Skv // bkv
    grid = (B, Hq, Sq // bq, kv_steps)
    kernel = functools.partial(
        _kernel, bq=bq, bkv=bkv, causal=causal, window=window,
        q_offset=q_offset, kv_steps=kv_steps, scale=1.0 / math.sqrt(D))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

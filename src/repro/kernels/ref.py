"""Pure-jnp oracles for every Pallas kernel.

Deliberately naive implementations (materialized logits, sequential
recurrences) — slow but obviously correct; the kernels are asserted
allclose against these across shape/dtype sweeps.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_prefill_ref(q, k, v, *, causal: bool = True, window=None,
                      q_offset: int = 0):
    """q [B, Hq, Sq, D], k/v [B, Hkv, Skv, D] -> [B, Hq, Sq, D].

    q_offset: absolute position of q[0] (chunked prefill against a longer
    KV prefix).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens):
    """Decode attention over paged KV.

    q [B, Hq, D]; k_pages/v_pages [P, page, Hkv, D];
    block_tables [B, pages_per_seq] int32; seq_lens [B] int32.
    """
    B, Hq, D = q.shape
    page = k_pages.shape[1]
    Hkv = k_pages.shape[2]
    G = Hq // Hkv
    pps = block_tables.shape[1]
    # gather each sequence's pages into a dense [B, S_max, Hkv, D]
    k = k_pages[block_tables].reshape(B, pps * page, Hkv, D)
    v = v_pages[block_tables].reshape(B, pps * page, Hkv, D)
    pos = jnp.arange(pps * page)
    valid = pos[None, :] < seq_lens[:, None]
    qg = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(D)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables,
                                q_start, q_lens):
    """Fused multi-token-query attention over paged KV (DESIGN.md §11).

    q [B, Q, Hq, D]; k_pages/v_pages [P, page, Hkv, D];
    block_tables [B, pages_per_seq] int32; q_start/q_lens [B] int32.
    Query token t of row b attends causally over global positions
    <= q_start[b] + t; tokens t >= q_lens[b] are padding (output
    unspecified — callers discard them; here they are zeroed so the
    oracle is deterministic).
    """
    B, Q, Hq, D = q.shape
    page = k_pages.shape[1]
    Hkv = k_pages.shape[2]
    G = Hq // Hkv
    pps = block_tables.shape[1]
    k = k_pages[block_tables].reshape(B, pps * page, Hkv, D)
    v = v_pages[block_tables].reshape(B, pps * page, Hkv, D)
    pos = jnp.arange(pps * page)
    t = jnp.arange(Q)
    limit = q_start[:, None] + t[None, :]              # [B, Q]
    valid = pos[None, None, :] <= limit[:, :, None]    # [B, Q, S]
    valid &= (t[None, :] < q_lens[:, None])[:, :, None]
    qg = q.reshape(B, Q, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(D)
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p, v.astype(jnp.float32))
    out = jnp.where(valid.any(-1)[..., None, None, None], out, 0.0)
    return out.reshape(B, Q, Hq, D).astype(q.dtype)


def ssd_scan_ref(X, dA, B_mat, C_mat, initial_state=None):
    """Sequential (token-by-token) SSD recurrence — the ground truth.

    X [B, L, H, P] (dt-scaled inputs), dA [B, L, H] log-decay,
    B_mat/C_mat [B, L, H, N]. Returns (Y [B, L, H, P], state [B, H, P, N]).
    """
    b, l, h, p = X.shape
    n = B_mat.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        x_t, da_t, b_t, c_t = inp
        state = state * jnp.exp(da_t)[..., None, None] \
            + x_t[..., :, None] * b_t[..., None, :]
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y_t

    xs = (X.transpose(1, 0, 2, 3).astype(jnp.float32),
          dA.transpose(1, 0, 2).astype(jnp.float32),
          B_mat.transpose(1, 0, 2, 3).astype(jnp.float32),
          C_mat.transpose(1, 0, 2, 3).astype(jnp.float32))
    state, ys = jax.lax.scan(step, initial_state, xs)
    return ys.transpose(1, 0, 2, 3).astype(X.dtype), state

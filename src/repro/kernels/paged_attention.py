"""Paged decode attention — Pallas TPU kernel.

One query token per sequence attends over a paged KV cache addressed
through per-sequence block tables. This is the TPU-native re-think of
vLLM-style CUDA paged attention (DESIGN.md §3): instead of warp-level
gather, each grid step DMAs one KV page HBM→VMEM, selected by a
scalar-prefetched block table (``PrefetchScalarGridSpec``), and folds it
into an online-softmax accumulator. Pages are contiguous [page, Hkv, D]
tiles so the MXU sees aligned [page, D] operands; G query heads of a KV
head are processed together as a [G, D] tile.

Grid: (B, Hkv, pages_per_seq) — pages innermost, accumulator in VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(block_tables, seq_lens, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, page: int, pages_per_seq: int,
            scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = seq_lens[b]
    base = p * page

    @pl.when(base < seq_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)           # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)           # [page, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pexp @ v
        m_ref[...] = m_new

    @pl.when(p == pages_per_seq - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                    interpret: bool = False):
    """q [B, Hq, D]; k_pages/v_pages [P, page, Hkv, D];
    block_tables [B, pages_per_seq] i32; seq_lens [B] i32 -> [B, Hq, D]."""
    B, Hq, D = q.shape
    num_pages, page, Hkv, _ = k_pages.shape
    G = Hq // Hkv
    pages_per_seq = block_tables.shape[1]
    grid = (B, Hkv, pages_per_seq)
    kernel = functools.partial(
        _kernel, page=page, pages_per_seq=pages_per_seq,
        scale=1.0 / math.sqrt(D))
    qg = q.reshape(B, Hkv, G, D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, p, bt, sl: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, p, bt, sl: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, p, bt, sl: (bt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, p, bt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)

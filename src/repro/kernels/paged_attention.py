"""Paged decode attention — Pallas TPU kernel.

One query token per sequence attends over a paged KV cache addressed
through per-sequence block tables. This is the TPU-native re-think of
vLLM-style CUDA paged attention (DESIGN.md §3): instead of warp-level
gather, each grid step DMAs one KV tile HBM→VMEM, selected by a
scalar-prefetched block table (``PrefetchScalarGridSpec``), and folds it
into an online-softmax accumulator. Pages are contiguous [page, Hkv, D]
tiles so the MXU sees aligned [page, D] operands; G query heads of a KV
head are processed together as a [G, D] tile.

Grid: (B, Hkv, pages_per_seq * page/kv_block) — KV tiles innermost,
accumulator in VMEM.

``paged_prefill_attention`` is the fused-round variant (DESIGN.md §11):
each batch row carries a *chunk* of Q consecutive query tokens (a
prefill chunk, a speculative draft window, or Q=1 for a decode slot)
whose KV was scattered into the pages before the call, with per-row
``q_start``/``q_lens`` scalars. Causal masking covers both the committed
history and the intra-chunk positions — query token t of a row attends
to global positions ``<= q_start + t`` — and each of the Q*G query rows
keeps its own online-softmax accumulator, so one launch serves an entire
mixed prefill+decode token budget (and the spec-decode verify step,
DESIGN.md §16, which is exactly this shape).

Both kernels also run as one *shard* of a tensor-sharded page store
(DESIGN.md §9): when the 'model' mesh axis splits each page's token
slots, a shard holds ``page_local = page / M`` slots of every physical
page, and ``pos_stride``/``pos_offset`` map local slot ``j`` of grid
page ``p`` back to its global position ``p * pos_stride + pos_offset +
j`` so the causal/length mask stays exact. ``return_stats`` additionally
emits the online-softmax running max ``m`` and denominator ``l`` per
(batch[, q-token], q-head) so the caller can combine partial softmaxes
across shards (the standard flash-merge: weight each shard's normalized
output by ``l_s * exp(m_s - max_s m_s)``).

Tiling knobs (DESIGN.md §16): ``kv_block`` splits each page into
``page / kv_block`` grid steps (smaller VMEM tiles, more steps —
arithmetic-identical at any legal value, because the online softmax
folds tiles in the same position order); ``head_block`` caps the KV
heads per launch, splitting the head axis across multiple
``pallas_call``s whose outputs concatenate (exact, by per-head softmax
independence). Both default to a static heuristic and are overridden
per (shape, backend) by the autotune cache when
``repro.kernels.autotune.enable()`` has loaded one — callers that pass
explicit values bypass the cache entirely.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _default_kv_block(page: int) -> int:
    """Static tile heuristic: whole-page tiles up to the 16-slot lane
    tile; larger 16-divisible pages default to 16-slot sub-tiles (the
    TPU lane width) — the autotune sweep overrides per shape."""
    return page if (page <= 16 or page % 16 != 0) else 16


def _resolve(kind: str, kv_block, head_block, *, page: int, Hkv: int,
             dims: dict) -> tuple:
    """Fill unset tiling knobs from the autotune cache (a no-op unless
    ``autotune.enable()`` loaded one), else the static defaults."""
    if kv_block is None or head_block is None:
        from repro.kernels import autotune
        tuned = autotune.lookup(kind, autotune.shape_key(**dims))
        if tuned is not None:
            if kv_block is None:
                kv_block = tuned.get("kv_block")
            if head_block is None:
                head_block = tuned.get("head_block")
    if kv_block is None:
        kv_block = _default_kv_block(page)
    if head_block is None:
        head_block = Hkv
    assert page % kv_block == 0, (page, kv_block)
    assert Hkv % head_block == 0, (Hkv, head_block)
    return kv_block, head_block


def _kernel(block_tables, seq_lens, q_ref, k_ref, v_ref, *refs,
            kv_block: int, bpp: int, total_steps: int, scale: float,
            pos_stride: int, pos_offset: int, stats: bool):
    if stats:
        o_ref, m_out_ref, l_out_ref, acc_ref, m_ref, l_ref = refs
    else:
        o_ref, acc_ref, m_ref, l_ref = refs
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = seq_lens[b]
    base = (p // bpp) * pos_stride + (p % bpp) * kv_block + pos_offset

    @pl.when(base < seq_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)           # [kv_block, D]
        v = v_ref[0, :, 0].astype(jnp.float32)           # [kv_block, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pexp @ v
        m_ref[...] = m_new

    @pl.when(p == total_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        if stats:
            m_out_ref[0, 0] = m_ref[...]
            l_out_ref[0, 0] = l_ref[...]


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                    pos_stride: int | None = None, pos_offset: int = 0,
                    return_stats: bool = False, interpret: bool = False,
                    kv_block: int | None = None,
                    head_block: int | None = None):
    """q [B, Hq, D]; k_pages/v_pages [P, page, Hkv, D];
    block_tables [B, pages_per_seq] i32; seq_lens [B] i32 -> [B, Hq, D].

    ``pos_stride``/``pos_offset`` map local page slot ``j`` of grid page
    ``p`` to global position ``p * pos_stride + pos_offset + j`` — the
    identity mapping by default; a slot-sharded caller passes the global
    page size and its shard's slot offset. With ``return_stats`` the
    result is ``(out, m, l)`` where ``m``/``l`` [B, Hq] f32 are the
    per-row online-softmax running max and denominator over this call's
    positions (``m = -inf``, ``l = 0`` for rows/shards with no valid
    position), enabling an exact cross-shard softmax merge.

    ``kv_block`` (divides ``page``) sizes the per-grid-step KV tile;
    ``head_block`` (divides ``Hkv``) splits the launch over the KV-head
    axis. Unset knobs come from the autotune cache when enabled, else
    static defaults. Any legal values are output-identical.
    """
    B, Hq, D = q.shape
    num_pages, page, Hkv, _ = k_pages.shape
    G = Hq // Hkv
    pages_per_seq = block_tables.shape[1]
    if pos_stride is None:
        pos_stride = page
    kv_block, head_block = _resolve(
        "paged_attention", kv_block, head_block, page=page, Hkv=Hkv,
        dims=dict(B=B, Hq=Hq, Hkv=Hkv, D=D, page=page,
                  pps=pages_per_seq))
    if head_block < Hkv:
        # split the KV-head axis into independent launches; exact
        # because each head's softmax never mixes with another's
        parts = [paged_attention(
            q[:, h0 * G:(h0 + head_block) * G],
            k_pages[:, :, h0:h0 + head_block],
            v_pages[:, :, h0:h0 + head_block],
            block_tables, seq_lens, pos_stride=pos_stride,
            pos_offset=pos_offset, return_stats=return_stats,
            interpret=interpret, kv_block=kv_block,
            head_block=head_block)
            for h0 in range(0, Hkv, head_block)]
        if return_stats:
            return tuple(jnp.concatenate([p[i] for p in parts], axis=1)
                         for i in range(3))
        return jnp.concatenate(parts, axis=1)
    bpp = page // kv_block
    total_steps = pages_per_seq * bpp
    grid = (B, Hkv, total_steps)
    kernel = functools.partial(
        _kernel, kv_block=kv_block, bpp=bpp, total_steps=total_steps,
        scale=1.0 / math.sqrt(D), pos_stride=pos_stride,
        pos_offset=pos_offset, stats=return_stats)
    qg = q.reshape(B, Hkv, G, D)
    out_specs = pl.BlockSpec((1, 1, G, D),
                             lambda b, h, p, bt, sl: (b, h, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype)
    if return_stats:
        stat_spec = pl.BlockSpec((1, 1, G),
                                 lambda b, h, p, bt, sl: (b, h, 0))
        stat_shape = jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32)
        out_specs = [out_specs, stat_spec, stat_spec]
        out_shape = [out_shape, stat_shape, stat_shape]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, p, bt, sl: (b, h, 0, 0)),
            pl.BlockSpec((1, kv_block, 1, D),
                         lambda b, h, p, bt, sl:
                         (bt[b, p // bpp], p % bpp, h, 0)),
            pl.BlockSpec((1, kv_block, 1, D),
                         lambda b, h, p, bt, sl:
                         (bt[b, p // bpp], p % bpp, h, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_tables, seq_lens, qg, k_pages, v_pages)
    if return_stats:
        o, m, l = out
        return (o.reshape(B, Hq, D), m.reshape(B, Hq), l.reshape(B, Hq))
    return out.reshape(B, Hq, D)


# ======================================================================
# fused multi-token queries (one launch per round — DESIGN.md §11)
# ======================================================================
def _fused_kernel(block_tables, q_start, q_lens, q_ref, k_ref, v_ref,
                  *refs, kv_block: int, bpp: int, total_steps: int,
                  scale: float, pos_stride: int, pos_offset: int,
                  stats: bool, Q: int, G: int):
    if stats:
        o_ref, m_out_ref, l_out_ref, acc_ref, m_ref, l_ref = refs
    else:
        o_ref, acc_ref, m_ref, l_ref = refs
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = q_start[b]
    nq = q_lens[b]
    seq_len = start + nq                 # post-write attention length
    base = (p // bpp) * pos_stride + (p % bpp) * kv_block + pos_offset

    @pl.when(base < seq_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [G*Q, D]
        k = k_ref[0, :, 0].astype(jnp.float32)           # [kv_block, D]
        v = v_ref[0, :, 0].astype(jnp.float32)           # [kv_block, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        kv_pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # query rows are (g, t) pairs, t minor: row r is token r % Q
        t_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % Q
        mask = (kv_pos <= start + t_idx) & (t_idx < nq)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pexp @ v
        m_ref[...] = m_new

    @pl.when(p == total_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        if stats:
            m_out_ref[0, 0] = m_ref[...]
            l_out_ref[0, 0] = l_ref[...]


def paged_prefill_attention(q, k_pages, v_pages, block_tables, q_start,
                            q_lens, *, pos_stride: int | None = None,
                            pos_offset: int = 0,
                            return_stats: bool = False,
                            interpret: bool = False,
                            kv_block: int | None = None,
                            head_block: int | None = None):
    """q [B, Q, Hq, D]; k_pages/v_pages [P, page, Hkv, D];
    block_tables [B, pages_per_seq] i32; q_start/q_lens [B] i32
    -> [B, Q, Hq, D].

    Row b's query token t sits at global position ``q_start[b] + t`` and
    attends causally over positions ``<= q_start[b] + t`` — the
    committed history plus the chunk prefix, whose KV the caller already
    scattered into the pages. Tokens ``t >= q_lens[b]`` are padding:
    fully masked, finite-garbage output, to be discarded (a row with
    ``q_lens == 0`` computes nothing and returns zeros). ``pos_stride``/
    ``pos_offset`` remap local page slots to global positions exactly as
    in ``paged_attention``; a slot-sharded caller shifts the *traced*
    ``q_start`` by its slot offset instead of passing a traced
    ``pos_offset``. With ``return_stats`` the result is ``(out, m, l)``
    with m/l [B, Q, Hq] f32 per query row, enabling the exact
    cross-shard softmax merge (fully-masked rows report ``m = NEG_INF``
    — a finite, hugely negative sentinel — so merge weights vanish
    without NaNs). ``kv_block``/``head_block`` tile exactly as in
    ``paged_attention``.
    """
    B, Q, Hq, D = q.shape
    num_pages, page, Hkv, _ = k_pages.shape
    G = Hq // Hkv
    pages_per_seq = block_tables.shape[1]
    if pos_stride is None:
        pos_stride = page
    kv_block, head_block = _resolve(
        "paged_prefill_attention", kv_block, head_block, page=page,
        Hkv=Hkv, dims=dict(B=B, Q=Q, Hq=Hq, Hkv=Hkv, D=D, page=page,
                           pps=pages_per_seq))
    if head_block < Hkv:
        parts = [paged_prefill_attention(
            q[:, :, h0 * G:(h0 + head_block) * G],
            k_pages[:, :, h0:h0 + head_block],
            v_pages[:, :, h0:h0 + head_block],
            block_tables, q_start, q_lens, pos_stride=pos_stride,
            pos_offset=pos_offset, return_stats=return_stats,
            interpret=interpret, kv_block=kv_block,
            head_block=head_block)
            for h0 in range(0, Hkv, head_block)]
        if return_stats:
            return tuple(jnp.concatenate([p[i] for p in parts], axis=2)
                         for i in range(3))
        return jnp.concatenate(parts, axis=2)
    bpp = page // kv_block
    total_steps = pages_per_seq * bpp
    grid = (B, Hkv, total_steps)
    kernel = functools.partial(
        _fused_kernel, kv_block=kv_block, bpp=bpp,
        total_steps=total_steps, scale=1.0 / math.sqrt(D),
        pos_stride=pos_stride, pos_offset=pos_offset,
        stats=return_stats, Q=Q, G=G)
    # [B, Q, (Hkv, G), D] -> [B, Hkv, G*Q, D]: rows are (g, t), t minor,
    # so the kernel recovers the token index as row % Q
    qg = jnp.moveaxis(q.reshape(B, Q, Hkv, G, D), 1, 3) \
        .reshape(B, Hkv, G * Q, D)
    out_specs = pl.BlockSpec((1, 1, G * Q, D),
                             lambda b, h, p, bt, qs, ql: (b, h, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, Hkv, G * Q, D), q.dtype)
    if return_stats:
        stat_spec = pl.BlockSpec((1, 1, G * Q),
                                 lambda b, h, p, bt, qs, ql: (b, h, 0))
        stat_shape = jax.ShapeDtypeStruct((B, Hkv, G * Q), jnp.float32)
        out_specs = [out_specs, stat_spec, stat_spec]
        out_shape = [out_shape, stat_shape, stat_shape]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G * Q, D),
                         lambda b, h, p, bt, qs, ql: (b, h, 0, 0)),
            pl.BlockSpec((1, kv_block, 1, D),
                         lambda b, h, p, bt, qs, ql:
                         (bt[b, p // bpp], p % bpp, h, 0)),
            pl.BlockSpec((1, kv_block, 1, D),
                         lambda b, h, p, bt, qs, ql:
                         (bt[b, p // bpp], p % bpp, h, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((G * Q, D), jnp.float32),
            pltpu.VMEM((G * Q,), jnp.float32),
            pltpu.VMEM((G * Q,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_tables, q_start, q_lens, qg, k_pages, v_pages)

    def unpack(x):                        # [B, Hkv, G*Q, ...] -> token-major
        tail = x.shape[3:]
        return jnp.moveaxis(x.reshape(B, Hkv, G, Q, *tail), 3, 1) \
            .reshape(B, Q, Hq, *tail)

    if return_stats:
        o, m, l = out
        return unpack(o), unpack(m), unpack(l)
    return unpack(out)

"""Kernel autotune harness (DESIGN.md §16).

Sweeps the paged-attention tiling knobs (``kv_block`` sub-page tiles,
``head_block`` KV heads per launch) per kernel shape, checks every
candidate against the pure-jnp oracle, gates the pick with a
roofline-style arithmetic-intensity model, and caches the winner in a
JSON store keyed by (kernel, shape, backend). The cache is consulted at
jit time by ``paged_attention``/``paged_prefill_attention`` — but ONLY
after ``enable(path)`` loads it into this module's process-global
state; with autotune disabled (the default) every lookup is a no-op and
the kernels run their static defaults, so serving stays bit-exact
unless explicitly opted in (the ``async_transfers=False`` pattern).

Cache format (invalidation rules):
  {"__meta__": {"version": 1},
   "<kind>|<shape_key>|<backend>": {"kv_block": int, "head_block": int,
                                    "measured_us": float,
                                    "default_us": float,
                                    "model_us": float, "reps": int}}
A version bump discards the whole file at load; the backend component
(``jax.default_backend()``) invalidates across CPU/TPU/GPU moves; any
shape not swept simply misses and falls back to the static defaults.
"""
from __future__ import annotations

import functools
import json
import os
import statistics
import time
from typing import Dict, Optional

import jax
import numpy as np

FORMAT_VERSION = 1

# modeled machine constants (roofline-style). Only candidate *ratios*
# matter — the gate compares configs of one shape against each other —
# so TPU-ish absolutes are fine even when sweeping the CPU interpret
# path.
C_LAUNCH_US = 5.0        # fixed pallas_call dispatch cost
C_STEP_US = 0.4          # per-grid-step overhead (DMA issue, control)
HBM_GB_S = 800.0         # KV stream bandwidth
PEAK_GFLOPS = 50_000.0   # MXU peak

_STATE: Dict[str, object] = {"path": None, "cache": {}, "hits": 0,
                             "misses": 0}


# ------------------------------------------------------------------ keys
def shape_key(**dims) -> str:
    """Canonical shape key: sorted ``k=v`` pairs."""
    return ",".join(f"{k}={dims[k]}" for k in sorted(dims))


def cache_key(kind: str, skey: str, backend: Optional[str] = None) -> str:
    return "|".join((kind, skey, backend or jax.default_backend()))


# ----------------------------------------------------------------- state
def enable(path: str) -> int:
    """Load (or start) the cache at ``path`` and turn lookups on.
    Returns the number of tuned entries loaded."""
    _STATE["path"] = path
    _STATE["cache"] = {}
    if os.path.exists(path):
        with open(path) as f:
            raw = json.load(f)
        meta = raw.pop("__meta__", {})
        if meta.get("version") == FORMAT_VERSION:
            _STATE["cache"] = raw
    return len(_STATE["cache"])


def disable() -> None:
    _STATE["path"] = None
    _STATE["cache"] = {}


def enabled() -> bool:
    return _STATE["path"] is not None


def lookup(kind: str, skey: str) -> Optional[dict]:
    """Tuned config for (kind, shape, current backend) — None unless
    ``enable()`` ran and the shape was swept."""
    if not enabled():
        return None
    ent = _STATE["cache"].get(cache_key(kind, skey))
    if ent is None:
        _STATE["misses"] += 1
    else:
        _STATE["hits"] += 1
    return ent


def stats() -> dict:
    return {"entries": len(_STATE["cache"]), "hits": _STATE["hits"],
            "misses": _STATE["misses"]}


def save(path: Optional[str] = None) -> str:
    path = path or _STATE["path"]
    assert path, "autotune.save() needs enable(path) or an explicit path"
    out = {"__meta__": {"version": FORMAT_VERSION}}
    out.update(_STATE["cache"])
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


# ------------------------------------------------------------ the model
def candidate_configs(page: int, Hkv: int) -> list:
    """The sweep space for one shape: every kv_block dividing the page
    (powers of two up to 128, plus whole-page and the static default),
    crossed with head_block in {1, Hkv}."""
    from repro.kernels.paged_attention import _default_kv_block
    kvs = sorted({b for b in (8, 16, 32, 64, 128) if page % b == 0}
                 | {page, _default_kv_block(page)})
    heads = sorted({Hkv} | ({1} if Hkv > 1 else set()))
    return [{"kv_block": kb, "head_block": hb}
            for kb in kvs for hb in heads]


def modeled_cost_us(*, B: int, Hkv: int, D: int, page: int, pps: int,
                    Q: int = 1, G: int = 1, kv_block: int,
                    head_block: int) -> float:
    """Arithmetic-intensity cost of one call under a candidate tiling:
    launch dispatches + grid-step overheads + the KV byte stream over
    HBM bandwidth + the attention flops at peak. Shared with
    ``benchmarks/roofline_report.py``'s framing: the bytes/flops terms
    are tiling-invariant, so the model ranks tilings purely by launch
    and step overhead — exactly the knobs the sweep moves."""
    launches = Hkv // head_block
    total_steps = B * Hkv * pps * (page // kv_block)
    kv_bytes = 2 * B * pps * page * Hkv * D * 4
    flops = 4 * B * Hkv * G * Q * pps * page * D
    return (launches * C_LAUNCH_US + total_steps * C_STEP_US
            + kv_bytes / (HBM_GB_S * 1e3)
            + flops / (PEAK_GFLOPS * 1e3))


# ------------------------------------------------------------- the sweep
def _sweep_inputs(kind: str, *, B, Hq, Hkv, D, page, pps, Q, seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    num_pages = B * pps + 1
    k_pages = jnp.asarray(rng.standard_normal(
        (num_pages, page, Hkv, D)), jnp.float32)
    v_pages = jnp.asarray(rng.standard_normal(
        (num_pages, page, Hkv, D)), jnp.float32)
    bt = jnp.asarray(rng.permutation(B * pps)[:B * pps]
                     .reshape(B, pps), jnp.int32)
    seq = jnp.full((B,), page * pps - 3, jnp.int32)
    if kind == "paged_attention":
        q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
        return (q, k_pages, v_pages, bt, seq)
    q = jnp.asarray(rng.standard_normal((B, Q, Hq, D)), jnp.float32)
    q_lens = jnp.full((B,), Q, jnp.int32)
    q_start = seq - Q
    return (q, k_pages, v_pages, bt, q_start, q_lens)


def sweep(kind: str, *, B: int, Hq: int, Hkv: int, D: int, page: int,
          pps: int, Q: int = 1, reps: int = 3, interpret: bool = True,
          seed: int = 0, gate_ratio: float = 4.0) -> dict:
    """Sweep one shape: time every correctness-checked candidate, gate
    by the arithmetic-intensity model (a candidate the model prices
    worse than ``gate_ratio``× the default is never eligible, however
    it happens to time on this host), pick the fastest measured, and
    keep the default on a measured tie-or-worse. Stores and returns the
    winning entry."""
    from repro.kernels import ref
    from repro.kernels import paged_attention as pk
    assert kind in ("paged_attention", "paged_prefill_attention"), kind
    fns = {"paged_attention": pk.paged_attention,
           "paged_prefill_attention": pk.paged_prefill_attention}
    dims = dict(B=B, Hq=Hq, Hkv=Hkv, D=D, page=page, pps=pps)
    if kind == "paged_prefill_attention":
        dims["Q"] = Q
    args = _sweep_inputs(kind, B=B, Hq=Hq, Hkv=Hkv, D=D, page=page,
                         pps=pps, Q=Q, seed=seed)
    oracle = {"paged_attention": ref.paged_attention_ref,
              "paged_prefill_attention": ref.paged_prefill_attention_ref}
    want = np.asarray(oracle[kind](*args))
    G = Hq // Hkv

    def timed(cfg) -> Optional[float]:
        fn = jax.jit(functools.partial(
            fns[kind], interpret=interpret, **cfg))
        out = np.asarray(jax.block_until_ready(fn(*args)))   # compile
        if kind == "paged_prefill_attention":
            # padding rows are unspecified — compare valid tokens only
            out = out[:, :Q]
            ok = np.allclose(out, want[:, :Q], rtol=1e-4, atol=1e-4)
        else:
            ok = np.allclose(out, want, rtol=1e-4, atol=1e-4)
        if not ok:
            return None
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            walls.append(time.perf_counter() - t0)
        return statistics.median(walls) * 1e6

    default = {"kv_block": pk._default_kv_block(page),
               "head_block": Hkv}
    default_us = timed(default)
    assert default_us is not None, "default config failed correctness"
    default_model = modeled_cost_us(B=B, Hkv=Hkv, D=D, page=page,
                                    pps=pps, Q=Q, G=G, **default)
    best, best_us, best_model = dict(default), default_us, default_model
    for cfg in candidate_configs(page, Hkv):
        if cfg == default:
            continue
        model_us = modeled_cost_us(B=B, Hkv=Hkv, D=D, page=page,
                                   pps=pps, Q=Q, G=G, **cfg)
        if model_us > gate_ratio * default_model:
            continue                     # roofline gate: never eligible
        us = timed(cfg)
        if us is None:
            continue                     # failed the oracle check
        if us < best_us:
            best, best_us, best_model = cfg, us, model_us
    entry = {**best, "measured_us": round(best_us, 3),
             "default_us": round(default_us, 3),
             "model_us": round(best_model, 3), "reps": reps}
    _STATE["cache"][cache_key(kind, shape_key(**dims))] = entry
    return entry

from repro.kernels.ops import (  # noqa: F401
    flash_prefill_op, paged_attention_op, ssd_scan_op,
)

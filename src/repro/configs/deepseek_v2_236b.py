"""deepseek-v2-236b — MoE with multi-head latent attention (MLA).

[arXiv:2405.04434; hf] 60L d_model=5120 128H d_ff(expert)=1536 vocab=102400,
MoE 160 routed experts top-6 + 2 shared; MLA kv_lora=512, q_lora=1536,
nope/v head 128, rope head 64. First layer is dense (d_ff=12288).
"""
from repro.configs.registry import MLAConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,       # MLA: per-head K/V decompressed from shared latent
    head_dim=128,
    d_ff=12288,             # dense-layer FFN width
    vocab_size=102400,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=160, top_k=6, num_shared_experts=2,
                  d_ff_expert=1536, capacity_factor=1.25,
                  first_dense_layers=1, d_ff_dense=12288),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  nope_head_dim=128, rope_head_dim=64, v_head_dim=128),
    source="arXiv:2405.04434",
))

"""paligemma-3b — SigLIP + gemma VLM; vision frontend stubbed.

[arXiv:2407.07726; hf] 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.
The SigLIP tower is a STUB: input_specs() provides precomputed patch
embeddings [B, 256, d_model] prepended to the token sequence (prefix-LM mask
over the patch prefix, causal over text — matching the PaliGemma recipe).
"""
from repro.configs.registry import ModelConfig, register

CONFIG = register(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp_kind="geglu",
    embed_scale=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    frontend="vision",
    frontend_len=256,
    source="arXiv:2407.07726",
))

"""Architecture config registry.

One dataclass family describes every assigned architecture; each
``configs/<id>.py`` instantiates the exact published config and registers it.
``reduced()`` derives the CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_ff_expert: int = 0            # per-expert FFN width
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    first_dense_layers: int = 0     # leading dense layers (deepseek-v2 style)
    d_ff_dense: int = 0             # FFN width of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    nope_head_dim: int
    rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    num_groups: int = 1
    conv_width: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0              # 0 -> d_model
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rglru", "rglru", "local_attn")
    local_window: int = 2048


@dataclass(frozen=True)
class EncoderConfig:
    num_layers: int
    num_frames: int                 # stub-frontend sequence length
    d_model: int = 0                # 0 -> same as decoder d_model


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    mlp_kind: str = "swiglu"        # swiglu | squared_relu | geglu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 1_000_000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: Optional[float] = None
    embed_scale: bool = False       # gemma-style sqrt(d) embedding scale
    # Sub-family configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    # Modality frontend (STUB: input_specs provides precomputed embeddings)
    frontend: Optional[str] = None  # audio | vision
    frontend_len: int = 0           # frames/patches prepended to the sequence
    # Attention lowering: einsum | surrogate (perf-pass, see
    # layers.gqa_attention docstring)
    attention_impl: str = "einsum"
    # Numerics
    param_dtype: str = "bfloat16"
    dtype: str = "bfloat16"
    # Reference for provenance
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if long_500k decode is tractable (bounded per-token state)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def num_params(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d
        for layer in range(L):
            total += self._layer_params(layer)
        if self.encoder is not None:
            ed = self.encoder.d_model or d
            # encoder self-attn (MHA) + MLP per layer
            per = 4 * ed * ed + 2 * ed * self.d_ff + 4 * ed
            total += self.encoder.num_layers * per
        return total

    def num_active_params(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.num_params()
        m = self.moe
        d, L = self.d_model, self.num_layers
        expert = 3 * d * m.d_ff_expert  # swiglu expert
        moe_layers = L - m.first_dense_layers
        inactive = moe_layers * (m.num_experts - m.top_k) * expert
        return self.num_params() - inactive

    def _layer_params(self, layer: int) -> int:
        d = self.d_model
        hd = self.resolved_head_dim
        n = 0
        # attention / mixer
        if self.family == "ssm":
            s = self.ssm
            d_in = d * s.expand
            n += d * (2 * d_in + 2 * s.num_groups * s.state_dim + d_in // s.head_dim)
            n += d_in * d  # out proj
            n += s.conv_width * (d_in + 2 * s.num_groups * s.state_dim)
        elif self.family == "hybrid" and self._block_kind(layer) == "rglru":
            r = self.rglru
            w = r.lru_width or d
            n += 2 * d * w + w * d + 2 * w * w + r.conv_width * w + 2 * w
        elif self.mla is not None:
            m = self.mla
            H = self.num_heads
            n += d * m.q_lora_rank + m.q_lora_rank * H * (m.nope_head_dim + m.rope_head_dim)
            n += d * (m.kv_lora_rank + m.rope_head_dim)
            n += m.kv_lora_rank * H * (m.nope_head_dim + m.v_head_dim)
            n += H * m.v_head_dim * d
        else:
            n += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
            n += self.num_heads * hd * d
        # mlp
        if self.moe is not None and layer >= self.moe.first_dense_layers:
            m = self.moe
            n += d * m.num_experts  # router
            n += (m.num_experts + m.num_shared_experts) * 3 * d * m.d_ff_expert
        elif self.moe is not None:
            n += 3 * d * self.moe.d_ff_dense
        elif self.family == "ssm":
            pass  # mamba2 has no separate MLP
        elif self.family == "hybrid" and self._block_kind(layer) == "rglru":
            n += 3 * d * self.d_ff
        else:
            mults = {"swiglu": 3, "geglu": 3, "squared_relu": 2, "gelu": 2}
            n += mults[self.mlp_kind] * d * self.d_ff
        return n

    def _block_kind(self, layer: int) -> str:
        if self.family != "hybrid":
            return "attn"
        pat = self.rglru.block_pattern
        return pat[layer % len(pat)]

    def block_kinds(self) -> Tuple[str, ...]:
        return tuple(self._block_kind(i) for i in range(self.num_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        whisper_tiny, h2o_danube_1_8b, qwen3_4b, nemotron_4_340b,
        qwen2_1_5b, recurrentgemma_9b, mamba2_1_3b, deepseek_v2_236b,
        phi3_5_moe, paligemma_3b,
    )


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 256) -> ModelConfig:
    """Smoke-test variant: same family/feature set, tiny dims."""
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    kw: dict = dict(
        name=cfg.name + "-reduced",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=max(8, d_model // heads),
        d_ff=d_model * 3,
        vocab_size=vocab,
        sliding_window=16 if cfg.sliding_window else None,
        param_dtype="float32",
        dtype="float32",
        frontend_len=8 if cfg.frontend else 0,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_ff_expert=d_model * 2,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            d_ff_dense=d_model * 2 if cfg.moe.first_dense_layers else 0)
    if cfg.mla:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                              nope_head_dim=16, rope_head_dim=8, v_head_dim=16)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16,
                                        chunk_size=16)
    if cfg.rglru:
        kw["rglru"] = dataclasses.replace(cfg.rglru, lru_width=d_model,
                                          local_window=16)
        kw["num_layers"] = 3  # one full (rglru, rglru, local_attn) group
    if cfg.encoder:
        kw["encoder"] = EncoderConfig(num_layers=2, num_frames=16)
    return cfg.replace(**kw)

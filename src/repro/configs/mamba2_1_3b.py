"""mamba2-1.3b — attention-free SSM with state-space duality (SSD).

[arXiv:2405.21060; unverified] 48L d_model=2048 d_ff=0 vocab=50280,
ssm_state=128, expand=2 (d_inner=4096), head_dim=64 (64 heads), conv=4.
"""
from repro.configs.registry import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=32,          # unused by the mixer; kept for API uniformity
    num_kv_heads=32,
    d_ff=0,
    vocab_size=50280,
    mlp_kind="gelu",
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, num_groups=1,
                  conv_width=4, chunk_size=256),
    source="arXiv:2405.21060",
))

from repro.configs.registry import (  # noqa: F401
    EncoderConfig, MLAConfig, MoEConfig, ModelConfig, RGLRUConfig, SSMConfig,
    get_config, list_configs, reduced, register,
)

"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf] 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
"""
from repro.configs.registry import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    mlp_kind="swiglu",
    sliding_window=4096,      # mistral-style SWA
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2401.16818",
))

"""recurrentgemma-9b — RG-LRU + local attention hybrid, 1:2 pattern.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000. Block pattern repeats (rglru, rglru, local_attn); 38 layers =
12 full groups + 2 trailing recurrent blocks (matches the Griffin recipe).
"""
from repro.configs.registry import ModelConfig, RGLRUConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mlp_kind="geglu",
    embed_scale=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4,
                      block_pattern=("rglru", "rglru", "local_attn"),
                      local_window=2048),
    source="arXiv:2402.19427",
))

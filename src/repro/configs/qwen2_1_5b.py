"""qwen2-1.5b — dense GQA with QKV bias.

[arXiv:2407.10671; hf] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.configs.registry import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mlp_kind="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="arXiv:2407.10671",
))

"""nemotron-4-340b — dense GQA with squared-ReLU MLP.

[arXiv:2402.16819; unverified] 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000.
"""
from repro.configs.registry import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    mlp_kind="squared_relu",
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2402.16819",
))

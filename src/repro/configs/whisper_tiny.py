"""whisper-tiny — enc-dec audio backbone, conv frontend stubbed.

[arXiv:2212.04356; unverified] 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
The modality frontend is a STUB: input_specs() provides precomputed frame
embeddings [B, num_frames, d_model] in place of the mel+conv stack.
"""
from repro.configs.registry import EncoderConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    mlp_kind="gelu",
    tie_embeddings=True,
    rope_theta=10_000.0,
    encoder=EncoderConfig(num_layers=4, num_frames=1500),
    frontend="audio",
    frontend_len=0,     # frontend feeds the encoder, not the decoder prefix
    source="arXiv:2212.04356",
))

"""qwen3-4b — dense GQA with qk_norm.

[hf:Qwen/Qwen3-8B family; hf] 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936. Qwen3 uses an explicit head_dim=128 (> d_model/num_heads).
"""
from repro.configs.registry import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    mlp_kind="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B",
))
